"""Pallas TPU tiled matmul (MXU-aligned, VMEM-blocked, fp32 accumulate).

Grid (M/bm, N/bn, K/bk) with the K loop innermost (sequential) so the
accumulator lives in VMEM scratch across K steps. Block sizes default to
(128, 128, 128): MXU-native tiles; the fp32 accumulator (bm x bn) plus the
two input tiles fit comfortably in ~16 MB VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# the TPU compiler-params dataclass was renamed across jax releases
_CompilerParams = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams")


def _matmul_kernel(x_ref, y_ref, o_ref, acc_ref, *, k_steps: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...], y_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _finish():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def matmul(x: jax.Array, y: jax.Array, *, bm: int = 128, bn: int = 128,
           bk: int = 128, interpret: bool = False) -> jax.Array:
    """x [M, K] @ y [K, N] -> [M, N]. Pads to block multiples."""
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, (x.shape, y.shape)
    mp, kp, np_ = (-(-m // bm) * bm, -(-k // bk) * bk, -(-n // bn) * bn)
    xp = jnp.pad(x, ((0, mp - m), (0, kp - k)))
    yp = jnp.pad(y, ((0, kp - k), (0, np_ - n)))
    k_steps = kp // bk
    out = pl.pallas_call(
        functools.partial(_matmul_kernel, k_steps=k_steps),
        grid=(mp // bm, np_ // bn, k_steps),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(xp, yp)
    return out[:m, :n]
