"""Pallas TPU RWKV-6 (Finch) WKV kernel: data-dependent-decay recurrence.

Per head, state S [Dk, Dv]:
    o_t = r_t . (S_{t-1} + diag(u) k_t^T v_t)
    S_t = diag(w_t) S_{t-1} + k_t^T v_t

Grid (B, H, T/bt), time innermost; S persists in VMEM scratch across time
blocks (initialized from the optional s0). The inner fori_loop performs
rank-1 outer-product updates [Dk, Dv] — VPU work with Dk*Dv elements per
step, matching the head sizes (64x64) of rwkv6-1.6b.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# the TPU compiler-params dataclass was renamed across jax releases
_CompilerParams = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams")


def _rwkv6_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, o_ref, sT_ref,
                  s_ref, *, bt: int, nt: int):
    it = pl.program_id(2)

    @pl.when(it == 0)
    def _init():
        s_ref[...] = s0_ref[0, 0].astype(jnp.float32)

    u = u_ref[0].astype(jnp.float32)                  # [Dk]

    def step(i, _):
        r_t = r_ref[0, 0, i, :].astype(jnp.float32)   # [Dk]
        k_t = k_ref[0, 0, i, :].astype(jnp.float32)
        v_t = v_ref[0, 0, i, :].astype(jnp.float32)   # [Dv]
        w_t = w_ref[0, 0, i, :].astype(jnp.float32)
        kv = k_t[:, None] * v_t[None, :]              # [Dk, Dv]
        s = s_ref[...]
        o_t = jnp.sum((s + u[:, None] * kv) * r_t[:, None], axis=0)  # [Dv]
        s_ref[...] = w_t[:, None] * s + kv
        o_ref[0, 0, i, :] = o_t.astype(o_ref.dtype)
        return ()

    jax.lax.fori_loop(0, bt, step, ())

    @pl.when(it == nt - 1)
    def _finish():
        sT_ref[0, 0] = s_ref[...].astype(sT_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bt", "interpret"))
def rwkv6(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
          u: jax.Array, s0: jax.Array | None = None, *,
          bt: int = 128, interpret: bool = False):
    """r,k,w [B,H,T,Dk], v [B,H,T,Dv], u [H,Dk] -> (o [B,H,T,Dv], S_T)."""
    b, h, t, dk = r.shape
    dv = v.shape[-1]
    if s0 is None:
        s0 = jnp.zeros((b, h, dk, dv), dtype=jnp.float32)
    bt = min(bt, t)
    tp = -(-t // bt) * bt
    pad4 = ((0, 0), (0, 0), (0, tp - t), (0, 0))
    rp, kp_, vp = (jnp.pad(x, pad4) for x in (r, k, v))
    # pad decay with ones so padded steps keep the state unchanged
    wp = jnp.pad(w, pad4, constant_values=1.0)
    nt = tp // bt
    o, sT = pl.pallas_call(
        functools.partial(_rwkv6_kernel, bt=bt, nt=nt),
        grid=(b, h, nt),
        in_specs=[
            pl.BlockSpec((1, 1, bt, dk), lambda ib, ih, it: (ib, ih, it, 0)),
            pl.BlockSpec((1, 1, bt, dk), lambda ib, ih, it: (ib, ih, it, 0)),
            pl.BlockSpec((1, 1, bt, dv), lambda ib, ih, it: (ib, ih, it, 0)),
            pl.BlockSpec((1, 1, bt, dk), lambda ib, ih, it: (ib, ih, it, 0)),
            pl.BlockSpec((1, dk), lambda ib, ih, it: (ih, 0)),
            pl.BlockSpec((1, 1, dk, dv), lambda ib, ih, it: (ib, ih, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, bt, dv), lambda ib, ih, it: (ib, ih, it, 0)),
            pl.BlockSpec((1, 1, dk, dv), lambda ib, ih, it: (ib, ih, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, tp, dv), v.dtype),
            jax.ShapeDtypeStruct((b, h, dk, dv), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((dk, dv), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(rp, kp_, vp, wp, u, s0)
    return o[:, :, :t], sT
