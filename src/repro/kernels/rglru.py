"""Pallas TPU RG-LRU scan (RecurrentGemma's gated linear recurrence).

    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * x_t

Grid (B/bb, T/bt) with time innermost/sequential; the carry h [bb, D]
persists in VMEM scratch across time blocks (re-initialized — from the
optional h0 — whenever a new batch block starts). Inside a block the
recurrence runs as a fori_loop over bt steps of fully-vectorized [bb, D]
VPU ops: batch/feature parallel, time sequential — the TPU-native layout
for this memory-bound scan.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# the TPU compiler-params dataclass was renamed across jax releases
_CompilerParams = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams")


def _rglru_kernel(x_ref, a_ref, h0_ref, y_ref, hT_ref, h_ref, *,
                  bt: int, nt: int):
    it = pl.program_id(1)

    @pl.when(it == 0)
    def _init():
        h_ref[...] = h0_ref[...].astype(jnp.float32)

    def step(i, _):
        a_t = a_ref[:, i, :].astype(jnp.float32)
        x_t = x_ref[:, i, :].astype(jnp.float32)
        g_t = jnp.sqrt(jnp.maximum(1.0 - a_t * a_t, 0.0)) * x_t
        h = a_t * h_ref[...] + g_t
        h_ref[...] = h
        y_ref[:, i, :] = h.astype(y_ref.dtype)
        return ()

    jax.lax.fori_loop(0, bt, step, ())

    @pl.when(it == nt - 1)
    def _finish():
        hT_ref[...] = h_ref[...].astype(hT_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bb", "bt", "interpret"))
def rglru(x: jax.Array, a: jax.Array, h0: jax.Array | None = None, *,
          bb: int = 8, bt: int = 128, interpret: bool = False):
    """x, a: [B, T, D] -> (y [B, T, D], h_T [B, D])."""
    b, t, d = x.shape
    if h0 is None:
        h0 = jnp.zeros((b, d), dtype=jnp.float32)
    bb = min(bb, b)
    bt = min(bt, t)
    bp, tp = -(-b // bb) * bb, -(-t // bt) * bt
    xp = jnp.pad(x, ((0, bp - b), (0, tp - t), (0, 0)))
    # pad decay with ones so padded steps keep the carry unchanged
    ap = jnp.pad(a, ((0, bp - b), (0, tp - t), (0, 0)), constant_values=1.0)
    h0p = jnp.pad(h0, ((0, bp - b), (0, 0)))
    nt = tp // bt
    y, hT = pl.pallas_call(
        functools.partial(_rglru_kernel, bt=bt, nt=nt),
        grid=(bp // bb, nt),
        in_specs=[
            pl.BlockSpec((bb, bt, d), lambda ib, it: (ib, it, 0)),
            pl.BlockSpec((bb, bt, d), lambda ib, it: (ib, it, 0)),
            pl.BlockSpec((bb, d), lambda ib, it: (ib, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bb, bt, d), lambda ib, it: (ib, it, 0)),
            pl.BlockSpec((bb, d), lambda ib, it: (ib, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bp, tp, d), x.dtype),
            jax.ShapeDtypeStruct((bp, d), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((bb, d), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(xp, ap, h0p)
    return y[:b, :t], hT[:b]
