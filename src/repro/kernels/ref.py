"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth).

These are also the *lowering* path used by the dry-run/roofline on the CPU
backend, so `cost_analysis()` FLOPs reflect the real math (DESIGN.md §2).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def matmul_ref(x: jax.Array, y: jax.Array) -> jax.Array:
    """Plain matmul in fp32 accumulation."""
    return jnp.dot(x, y, preferred_element_type=jnp.float32).astype(x.dtype)


def _expand_kv(k: jax.Array, n_q_heads: int) -> jax.Array:
    """[B, Hkv, S, D] -> [B, Hq, S, D] by repeating each kv head."""
    b, hkv, s, d = k.shape
    group = n_q_heads // hkv
    return jnp.repeat(k, group, axis=1)


def flash_attention_ref(
    q: jax.Array,          # [B, Hq, Sq, D]
    k: jax.Array,          # [B, Hkv, Sk, D]
    v: jax.Array,          # [B, Hkv, Sk, D]
    causal: bool = True,
    window: Optional[int] = None,   # local attention window (None = full)
    scale: Optional[float] = None,
) -> jax.Array:
    """Reference multi-head attention with GQA, causal and sliding-window
    masks. O(S^2) memory — oracle only."""
    b, hq, sq, d = q.shape
    scale = (d ** -0.5) if scale is None else scale
    kk = _expand_kv(k, hq)
    vv = _expand_kv(v, hq)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        kk.astype(jnp.float32)) * scale
    sk = k.shape[2]
    q_pos = jnp.arange(sq)[:, None] + (sk - sq)   # right-aligned queries
    k_pos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), dtype=bool)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    logits = jnp.where(mask[None, None], logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, vv.astype(jnp.float32))
    return out.astype(q.dtype)


def flash_decode_ref(
    q: jax.Array,          # [B, Hq, D] single new token
    k: jax.Array,          # [B, Hkv, S, D] cache
    v: jax.Array,          # [B, Hkv, S, D]
    length: Optional[jax.Array] = None,  # [B] valid cache lengths
    scale: Optional[float] = None,
) -> jax.Array:
    b, hq, d = q.shape
    out = flash_attention_ref(q[:, :, None], k, v, causal=False, scale=scale)
    if length is not None:
        # mask out positions >= length before softmax: recompute with mask
        kk = _expand_kv(k, hq)
        vv = _expand_kv(v, hq)
        s = (d ** -0.5) if scale is None else scale
        logits = jnp.einsum("bhd,bhkd->bhk", q.astype(jnp.float32),
                            kk.astype(jnp.float32)) * s
        valid = jnp.arange(k.shape[2])[None, :] < length[:, None]
        logits = jnp.where(valid[:, None], logits, -jnp.inf)
        p = jax.nn.softmax(logits, axis=-1)
        return jnp.einsum("bhk,bhkd->bhd", p, vv.astype(jnp.float32)).astype(q.dtype)
    return out[:, :, 0]


def rglru_ref(x: jax.Array, a: jax.Array, h0: Optional[jax.Array] = None
              ) -> tuple[jax.Array, jax.Array]:
    """RG-LRU linear recurrence (RecurrentGemma):

        h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * x_t

    x, a: [B, T, D] (a in (0,1)); returns (y [B,T,D], h_T [B,D])."""
    x32, a32 = x.astype(jnp.float32), a.astype(jnp.float32)
    gated = jnp.sqrt(jnp.maximum(1.0 - a32 ** 2, 0.0)) * x32

    def step(h, ts):
        a_t, g_t = ts
        h = a_t * h + g_t
        return h, h

    init = jnp.zeros_like(x32[:, 0]) if h0 is None else h0.astype(jnp.float32)
    hT, ys = jax.lax.scan(step, init,
                          (a32.swapaxes(0, 1), gated.swapaxes(0, 1)))
    return ys.swapaxes(0, 1).astype(x.dtype), hT


def acd_evict_ref(P: jax.Array, thresh: jax.Array, mask: jax.Array
                  ) -> jax.Array:
    """Greedy ACD evict set per queue row (oracle for `acd_sweep`).

    Left-to-right scan over each [B, J] row carrying the running *kept*
    demand sum: a masked job evicts iff the kept prefix ahead of it
    exceeds its threshold, else its demand joins the prefix. Equals the
    DES's iterated remove-first-violator-and-resweep fixpoint (removing
    the first violator never changes earlier prefix sums, so the
    iteration telescopes into this single pass).
    """
    def step(s, ts):
        p, t, m = ts                                   # each [B]
        ev = m & (s > t)
        return s + jnp.where(m & ~ev, p, 0.0), ev

    s0 = jnp.zeros(P.shape[:-1], P.dtype)
    _, evs = jax.lax.scan(
        step, s0, (jnp.moveaxis(P, -1, 0), jnp.moveaxis(thresh, -1, 0),
                   jnp.moveaxis(mask, -1, 0)))
    return jnp.moveaxis(evs, 0, -1)


def fifo_dispatch_ref(order: jax.Array, locpub: jax.Array,
                      n_pub: jax.Array, ready: jax.Array, dur: jax.Array,
                      selc: jax.Array, occ: jax.Array, seg: jax.Array,
                      capped_p: jax.Array, wu_p: jax.Array,
                      sclk0: jax.Array, sidle0: jax.Array, keep_alive,
                      cold: bool = False):
    """Capped FIFO dispatch chain (oracle for `dispatch`): jobs visit in
    ``order`` (public first, ``n_pub`` of them); each takes every
    provider's earliest-free slot from the [P, C] clock pool, prices its
    wait (+ warm-up when the slot idled past ``keep_alive``) into the
    argmin as occupancy $/s, and advances the chosen provider's slot
    clock. Mirrors the vector engine's ``slot_step`` / the DES's
    ``_start_public_capped`` expression for expression."""
    J = order.shape[-1]
    P = ready.shape[0]
    iota_P = jnp.arange(P)
    ka = jnp.asarray(keep_alive, ready.dtype)

    def body(i, c):
        sclk, sidle, prov_o, seg_o, wait_o, cold_o, start_o, end_o, \
            extra_o = c
        j = order[i]
        ready_p = ready[:, j]
        si = jnp.argmin(sclk, axis=1)
        sc_sel = sclk[iota_P, si]
        wait_p = jnp.where(capped_p, jnp.maximum(0.0, sc_sel - ready_p),
                           0.0)
        if cold:
            idle_sel = sidle[iota_P, si]
            cold_p = capped_p & ((ready_p + wait_p - idle_sel > ka)
                                 | jnp.isneginf(idle_sel))
        else:
            cold_p = jnp.zeros(P, dtype=bool)
        pen = occ[:, j] * (wait_p + cold_p * wu_p)
        prov = jnp.argmin(selc[:, j] + pen)
        start = ready_p[prov] + wait_p[prov] + cold_p[prov] * wu_p[prov]
        end = start + dur[prov, j]
        prov_o = prov_o.at[j].set(prov.astype(prov_o.dtype))
        seg_o = seg_o.at[j].set(seg[prov, j].astype(seg_o.dtype))
        wait_o = wait_o.at[j].set(wait_p[prov])
        cold_o = cold_o.at[j].set(cold_p[prov])
        start_o = start_o.at[j].set(start)
        end_o = end_o.at[j].set(end)
        extra_o = extra_o.at[j].set(pen[prov])
        upd = capped_p[prov]
        sclk = jnp.where(upd, sclk.at[prov, si[prov]].set(end), sclk)
        sidle = jnp.where(upd, sidle.at[prov, si[prov]].set(end), sidle)
        return (sclk, sidle, prov_o, seg_o, wait_o, cold_o, start_o,
                end_o, extra_o)

    f = ready.dtype
    out = jax.lax.fori_loop(
        0, n_pub.astype(jnp.int32), body,
        (sclk0, sidle0, jnp.zeros(J, jnp.int32), jnp.zeros(J, jnp.int32),
         jnp.zeros(J, f), jnp.zeros(J, bool), jnp.zeros(J, f),
         jnp.zeros(J, f), jnp.zeros(J, f)))
    return out[2:]


def rwkv6_ref(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
              u: jax.Array, s0: Optional[jax.Array] = None
              ) -> tuple[jax.Array, jax.Array]:
    """RWKV-6 (Finch) WKV recurrence with data-dependent decay.

    r,k,w: [B, H, T, Dk]; v: [B, H, T, Dv]; u: [H, Dk].
        o_t = r_t . (S_{t-1} + diag(u) k_t^T v_t)
        S_t = diag(w_t) S_{t-1} + k_t^T v_t          (w_t in (0,1))
    Returns (o [B,H,T,Dv], S_T [B,H,Dk,Dv])."""
    r32, k32, v32, w32 = (t.astype(jnp.float32) for t in (r, k, v, w))
    b, h, t, dk = r32.shape
    dv = v32.shape[-1]

    def step(S, ts):
        r_t, k_t, v_t, w_t = ts                       # [B,H,Dk]/[B,H,Dv]
        kv = k_t[..., :, None] * v_t[..., None, :]    # [B,H,Dk,Dv]
        o = jnp.einsum("bhk,bhkv->bhv", r_t, S + u[None, :, :, None] * kv)
        S = w_t[..., None] * S + kv
        return S, o

    init = (jnp.zeros((b, h, dk, dv), jnp.float32) if s0 is None
            else s0.astype(jnp.float32))
    ST, os_ = jax.lax.scan(
        step, init,
        tuple(x.swapaxes(0, 2).swapaxes(1, 2)      # [T,B,H,...]
              for x in (r32, k32, v32, w32)))
    return os_.swapaxes(0, 1).swapaxes(1, 2).astype(v.dtype), ST
