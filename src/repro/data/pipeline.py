"""Deterministic synthetic data pipeline (token LM + modality stubs).

Seeded, stateless indexing (batch i is a pure function of (seed, i)) so a
restarted/elastically-rescaled job resumes mid-epoch with no skew: every
host computes exactly the global batch slice it needs.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator

import numpy as np

from ..models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seq_len: int
    global_batch: int
    seed: int = 0
    # zipf-ish synthetic token distribution; loss curves behave sanely
    zipf_a: float = 1.2


class SyntheticLM:
    """Deterministic LM batches: tokens [B, S], labels, loss_mask."""

    def __init__(self, cfg: ModelConfig, dcfg: DataConfig):
        self.cfg = cfg
        self.dcfg = dcfg
        rng = np.random.default_rng(dcfg.seed)
        # fixed rank-correlated markov-ish table => learnable structure
        v = cfg.vocab_size
        self._freq = 1.0 / np.power(np.arange(1, v + 1), dcfg.zipf_a)
        self._freq /= self._freq.sum()
        self._shift = int(rng.integers(1, max(v - 1, 2)))

    def batch(self, index: int) -> Dict[str, np.ndarray]:
        d, c = self.dcfg, self.cfg
        rng = np.random.default_rng((d.seed, index))
        b, s = d.global_batch, d.seq_len
        base = rng.choice(c.vocab_size, size=(b, s), p=self._freq)
        # inject predictable structure: even positions follow prev + shift
        nxt = (base + self._shift) % c.vocab_size
        toks = np.where(np.arange(s)[None, :] % 2 == 1,
                        np.roll(nxt, 1, axis=1), base).astype(np.int32)
        labels = np.concatenate([toks[:, 1:], toks[:, :1]], axis=1)
        mask = np.ones((b, s), np.float32)
        mask[:, -1] = 0.0
        out = {"tokens": toks, "labels": labels.astype(np.int32),
               "loss_mask": mask}
        if c.vision_patches:
            out["patches"] = rng.normal(
                0, 0.02, (b, c.vision_patches, c.d_model)).astype(np.float32)
        if c.is_encdec:
            out["frames"] = rng.normal(
                0, 0.02, (b, c.encoder_seq, c.d_model)).astype(np.float32)
        return out

    def iterate(self, start: int = 0) -> Iterator[Dict[str, np.ndarray]]:
        i = start
        while True:
            yield self.batch(i)
            i += 1
