# Deterministic synthetic data pipelines (stateless indexing: resumable
# and elastic without skew).
from .pipeline import DataConfig, SyntheticLM

__all__ = ["DataConfig", "SyntheticLM"]
